"""Resilience bench: bitwise resume, checkpoint overhead, chaos recovery.

Three claims, asserted by ``benchmarks.check_gates`` (docs/RESILIENCE.md):

* **Resume is bitwise** (``resume_bitwise``): for every registry
  algorithm on the dense backend — plus a sign1bit+EF compressed config,
  whose ``{e, ref}`` wire state rides in the scan carry — a run killed
  at an arbitrary step and resumed from its snapshot reproduces the
  uninterrupted ``run_traced`` metric trace bit for bit.  Recovery
  wall-time (rebuild + restore + replay to the end) is reported per
  algorithm.

* **Checkpointing is cheap** (``checkpoint_overhead_pct``): the chunked
  resumable runner at ``checkpoint_every=50`` — snapshot writes included
  — stays within ``OVERHEAD_GATE_PCT`` (10%) of the single-scan
  ``run_traced`` wall-clock.  Both paths are warmed first, so the
  comparison is steady-state stepping, not compilation.

* **Chaos completes at matched stationarity** (``chaos_completed`` /
  ``chaos_matched_stationarity``): a seeded fault plan with three
  process kills, a NaN wire payload, a corrupt + a deleted checkpoint,
  and transient write failures finishes the Section-6 instance with
  zero manual intervention, and its final eq.-11 metric matches the
  fault-free run (bitwise resume makes the tolerance exact).  The
  wasted-steps column quantifies the replay cost of each
  ``checkpoint_every`` choice.

Dumped to ``BENCH_resilience.json``.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import ALGORITHMS, Row, make_setup, metric_fn_of
from repro.consensus import CompressionConfig
from repro.resilience import (FaultPlan, chaos_run, make_fault, resume_run,
                              run_resumable)
from repro.resilience.runner import SimulatedKill
from repro.solvers import SolverConfig, make_solver

ITERS = 40
REC = 10
KILL_AT = 23            # mid-chunk, not boundary-aligned: the hard case
CKPT_EVERY = 7          # co-prime with REC so boundaries never align
OVERHEAD_ITERS = 200
OVERHEAD_CKPT = 50      # the gate's stated cadence
OVERHEAD_GATE_PCT = 10.0
CHAOS_SEED = 1


def _json_path() -> str:
    return os.path.join(os.environ.get("BENCH_JSON_DIR", os.getcwd()),
                        "BENCH_resilience.json")


def _fresh(cfg, s):
    solver = make_solver(cfg)
    state = solver.init(None, s.prob, s.hg, s.x0, s.y0, s.data)
    return solver, state


def _kill_resume_case(name, cfg, s, iters, rec, rows, cases):
    """One kill/resume parity measurement -> (bitwise, recovery_s)."""
    metric = metric_fn_of(s)
    solver, state = _fresh(cfg, s)
    _, ref = solver.run_traced(state, s.data, iters, rec, metric)
    ref = np.asarray(jax.device_get(ref))

    with tempfile.TemporaryDirectory() as ckpt:
        plan = FaultPlan([make_fault("kill", step=KILL_AT)], seed=0)
        solver2, state2 = _fresh(cfg, s)
        try:
            run_resumable(solver2, state2, s.data, iters, rec, metric,
                          checkpoint_every=CKPT_EVERY, ckpt_dir=ckpt,
                          hooks=plan)
            raise RuntimeError("kill fault never fired")
        except SimulatedKill:
            pass
        t0 = time.perf_counter()
        _, _, trace = resume_run(cfg, ckpt, iters, rec, metric,
                                 checkpoint_every=CKPT_EVERY,
                                 problem=s.prob, x0=s.x0, y0=s.y0,
                                 data=s.data)
        recovery = time.perf_counter() - t0
    bitwise = np.asarray(trace).tobytes() == ref.tobytes()
    rows.append(Row(
        f"resilience_resume_{name}", 1e6 * recovery / iters,
        f"bitwise={bitwise};killed_at={KILL_AT};"
        f"checkpoint_every={CKPT_EVERY};recovery_s={recovery:.3f}"))
    cases.append({"name": name, "bitwise": bool(bitwise),
                  "killed_at": KILL_AT, "recovery_s": recovery})
    return bool(bitwise)


def run(smoke: bool = False) -> list:
    import json

    iters = 24 if smoke else ITERS
    rec = 6 if smoke else REC
    ov_iters = 100 if smoke else OVERHEAD_ITERS

    s = make_setup(m=5)
    rows: list = []
    cases: list = []
    dump: dict = {"bench": "resilience", "jax": jax.__version__,
                  "iters": iters, "record_every": rec,
                  "checkpoint_every": CKPT_EVERY,
                  "overhead_gate_pct": OVERHEAD_GATE_PCT,
                  "overhead_checkpoint_every": OVERHEAD_CKPT}

    # -- kill/resume bitwise parity, per algorithm + compressed+EF -------
    bitwise_all = True
    for algo in ALGORITHMS:
        cfg = SolverConfig(algo=algo, alpha=0.3, beta=0.3, mixing=s.spec,
                           hypergrad=s.hg)
        bitwise_all &= _kill_resume_case(algo, cfg, s, iters, rec, rows,
                                         cases)
    ef_cfg = SolverConfig(
        algo="interact", alpha=0.3, beta=0.3, mixing=s.spec,
        hypergrad=s.hg,
        compression=CompressionConfig(kind="sign1bit",
                                      error_feedback=True))
    bitwise_all &= _kill_resume_case("interact_sign1bit_ef", ef_cfg, s,
                                     iters, rec, rows, cases)
    dump["resume_cases"] = cases
    dump["resume_bitwise"] = bool(bitwise_all)

    # -- checkpoint overhead at checkpoint_every=50 ----------------------
    metric = metric_fn_of(s)
    base_cfg = SolverConfig(algo="interact", alpha=0.3, beta=0.3,
                            mixing=s.spec, hypergrad=s.hg)

    def time_plain():
        solver, state = _fresh(base_cfg, s)
        solver.warmup(state, s.data)     # engine caches warm
        t0 = time.perf_counter()
        _, tr = solver.run_traced(state, s.data, ov_iters, rec, metric)
        jax.block_until_ready(tr)
        return time.perf_counter() - t0

    def time_ckpt(ckpt):
        solver, state = _fresh(base_cfg, s)
        solver.warmup(state, s.data)
        t0 = time.perf_counter()
        run_resumable(solver, state, s.data, ov_iters, rec, metric,
                      checkpoint_every=OVERHEAD_CKPT, ckpt_dir=ckpt)
        return time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as ckpt:
        time_plain(), time_ckpt(ckpt)          # compile both programs
        t_plain = time_plain()
        t_ckpt = time_ckpt(os.path.join(ckpt, "timed"))
    overhead = 100.0 * (t_ckpt - t_plain) / max(t_plain, 1e-9)
    overhead = max(overhead, 0.0)   # scheduler noise can go "negative"
    dump["checkpoint_overhead_pct"] = overhead
    dump["plain_s"] = t_plain
    dump["checkpointed_s"] = t_ckpt
    rows.append(Row(
        "resilience_overhead", 1e6 * t_ckpt / ov_iters,
        f"overhead_pct={overhead:.2f};checkpoint_every={OVERHEAD_CKPT};"
        f"iters={ov_iters};plain_s={t_plain:.3f}"))

    # -- chaos campaign on the Section-6 instance ------------------------
    solver, state = _fresh(base_cfg, s)
    _, clean = solver.run_traced(state, s.data, iters, rec, metric)
    clean_final = float(np.asarray(clean)[-1])

    kill_steps = (iters // 4, iters // 2, 3 * iters // 4)
    with tempfile.TemporaryDirectory() as ckpt:
        plan = FaultPlan([
            make_fault("kill", step=kill_steps[0]),
            make_fault("kill", step=kill_steps[1]),
            make_fault("kill", step=kill_steps[2]),
            make_fault("nan-payload", step=iters // 3),
            make_fault("corrupt-checkpoint", step=iters // 2,
                       mode="garbage"),
            make_fault("stale-checkpoint", step=2 * iters // 3),
            make_fault("write-failure", step=iters // 4, count=2),
        ], seed=CHAOS_SEED)
        rep = chaos_run(base_cfg, plan, iters, rec,
                        checkpoint_every=CKPT_EVERY, ckpt_dir=ckpt,
                        metric_fn=metric, problem=s.prob, x0=s.x0,
                        y0=s.y0, data=s.data)
    matched = (rep.final_metric is not None
               and np.isclose(rep.final_metric, clean_final,
                              rtol=1e-6, atol=1e-9))
    chaos_bitwise = (rep.trace is not None and
                     rep.trace.tobytes()
                     == np.asarray(jax.device_get(clean)).tobytes())
    dump["chaos"] = {
        "completed": rep.completed, "restarts": rep.restarts,
        "kills": rep.kills, "nonfinite_faults": rep.nonfinite_faults,
        "write_retries": rep.write_retries,
        "wasted_steps": rep.wasted_steps, "wall_time_s": rep.wall_time_s,
        "final_metric": rep.final_metric, "clean_final": clean_final,
        "trace_bitwise": bool(chaos_bitwise),
        "events": rep.events,
    }
    dump["chaos_completed"] = bool(rep.completed)
    dump["chaos_matched_stationarity"] = bool(matched)
    rows.append(Row(
        "resilience_chaos", 1e6 * rep.wall_time_s / iters,
        f"completed={rep.completed};restarts={rep.restarts};"
        f"kills={rep.kills};wasted_steps={rep.wasted_steps};"
        f"final={rep.final_metric};matched={bool(matched)}"))

    # -- wasted steps vs checkpoint_every (replay-cost trade-off) --------
    wasted_rows = []
    for ce in (5, 10, 20):
        kill = int(iters * 0.6) + 1
        wasted = kill - (kill // ce) * ce   # lost work for a kill there
        wasted_rows.append({"checkpoint_every": ce, "kill_at": kill,
                            "wasted_steps": wasted})
        rows.append(Row(
            f"resilience_wasted_ce{ce}", 0.0,
            f"checkpoint_every={ce};kill_at={kill};"
            f"wasted_steps={wasted}"))
    dump["wasted_by_checkpoint_every"] = wasted_rows

    try:
        with open(_json_path(), "w") as fh:
            json.dump(dump, fh, indent=1)
    except OSError:
        pass  # read-only workdir: CSV rows still carry everything
    rows.append(Row(
        "resilience_headline", 0.0,
        f"resume_bitwise={bitwise_all};"
        f"checkpoint_overhead_pct={overhead:.2f};"
        f"chaos_completed={rep.completed};"
        f"chaos_matched_stationarity={bool(matched)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
