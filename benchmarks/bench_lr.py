"""Paper Fig. 5: impact of the learning rates alpha = beta.

Claim validated: larger (stable) step sizes converge faster for both
INTERACT and SVR-INTERACT.

Step sizes are a *batch axis* of the sweep engine (the parameterised
step bodies take alpha/beta as traced scalars), so the whole
learning-rate grid of one algorithm — every lr x every seed — is a
single ``jax.vmap``-batched XLA dispatch: 2 dispatches for the full
figure instead of one python loop per (algo, lr, seed) cell.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import (Row, make_setup, metric_fn_of,
                               record_sweep_section)
from repro.solvers import SolverConfig, expand_grid, sweep

ITERS = 40
LRS = (0.5, 0.1, 0.01, 0.001)
SEEDS = (0, 1, 2)


def run(smoke: bool = False) -> list:
    iters = 10 if smoke else ITERS
    seeds = SEEDS[:2] if smoke else SEEDS
    rows, records = [], []
    s = make_setup(m=5)
    mfn = metric_fn_of(s)
    for algo in ("interact", "svr-interact"):
        configs = expand_grid(
            SolverConfig(algo=algo, mixing=s.spec, hypergrad=s.hg),
            alpha=LRS, seed=seeds)
        # alpha and beta sweep together (the figure sets alpha = beta)
        configs = [dataclasses.replace(c, beta=c.alpha) for c in configs]
        res = sweep(configs, iters, rec := 5, problem=s.prob, x0=s.x0,
                    y0=s.y0, data=s.data, metric_fn=mfn, measure=True)
        assert res.num_dispatches == 1  # lr/seed are batch axes: one program

        finals = []
        us = 1e6 * res.seconds / (len(configs) * iters)
        for lr in LRS:
            idx = [i for i, c in enumerate(res.configs) if c.alpha == lr]
            traces = res.traces[idx]
            mean, std = traces.mean(axis=0), traces.std(axis=0)
            finals.append(float(mean[-1]))
            rows.append(Row(f"fig5_lr{lr}_{algo}", us,
                            f"final_metric={mean[-1]:.5f};"
                            f"final_std={std[-1]:.5f};seeds={len(seeds)}"))
            records.append({"name": f"fig5_lr{lr}_{algo}", "algo": algo,
                            "lr": lr, "seeds": len(seeds), "iters": iters,
                            "record_every": rec,
                            "trace_mean": mean.tolist(),
                            "trace_std": std.tolist()})
        monotone = all(finals[i] <= finals[i + 1] * 1.5
                       for i in range(len(finals) - 1))
        rows.append(Row(f"fig5_claim_{algo}_larger_lr_faster", 0.0,
                        f"holds={monotone}"))
        records.append({"name": f"fig5_claim_{algo}", "holds": monotone,
                        "dispatches": res.num_dispatches,
                        "grid_cells": len(configs)})
    record_sweep_section("lr", records)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
