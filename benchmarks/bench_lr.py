"""Paper Fig. 5: impact of the learning rates alpha = beta.

Claim validated: larger (stable) step sizes converge faster for both
INTERACT and SVR-INTERACT.
"""
from __future__ import annotations

from benchmarks.common import Row, make_setup, run_algo

ITERS = 40
LRS = (0.5, 0.1, 0.01, 0.001)


def run(smoke: bool = False) -> list:
    iters = 10 if smoke else ITERS
    rows = []
    s = make_setup(m=5)
    for algo in ("interact", "svr-interact"):
        finals = []
        for lr in LRS:
            trace, us, _ = run_algo(s, algo, iters, alpha=lr, beta=lr)
            finals.append(trace[-1])
            rows.append(Row(f"fig5_lr{lr}_{algo}", us,
                            f"final_metric={trace[-1]:.5f}"))
        monotone = all(finals[i] <= finals[i + 1] * 1.5
                       for i in range(len(finals) - 1))
        rows.append(Row(f"fig5_claim_{algo}_larger_lr_faster", 0.0,
                        f"holds={monotone}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
