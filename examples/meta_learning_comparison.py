"""Paper Section-6 experiment: all four algorithms compared.

Reproduces the Fig. 2 comparison (INTERACT, SVR-INTERACT, GT-DSGD, D-SGD)
on the synthetic meta-learning task and prints an ASCII convergence plot
(mean over seeds) plus the measured sample counts per agent (Table-1
style).  The whole seeds x algorithms grid runs through the batched
sweep engine (``repro.solvers.sweep``, docs/SWEEPS.md): one compiled
``init -> run_traced`` program per algorithm, metric recorded in-scan —
4 XLA dispatches for the 4 x len(SEEDS) grid.

    PYTHONPATH=src python examples/meta_learning_comparison.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import ALGORITHMS, make_setup, metric_fn_of

ITERS = 40
RECORD = 5
SEEDS = (0, 1, 2, 3)


def ascii_plot(traces: dict, width: int = 60, height: int = 14) -> str:
    all_vals = [v for t in traces.values() for v in t]
    lo = np.log10(max(min(all_vals), 1e-8))
    hi = np.log10(max(all_vals))
    rows = [[" "] * width for _ in range(height)]
    markers = {"interact": "I", "svr-interact": "S", "gt-dsgd": "G",
               "d-sgd": "D"}
    for name, trace in traces.items():
        for i, v in enumerate(trace):
            xpos = int(i / max(len(trace) - 1, 1) * (width - 1))
            ynorm = (np.log10(max(v, 1e-8)) - lo) / max(hi - lo, 1e-9)
            ypos = height - 1 - int(ynorm * (height - 1))
            rows[ypos][xpos] = markers[name]
    out = [f"log10(M): {hi:.1f}"]
    out += ["".join(r) for r in rows]
    out.append(f"log10(M): {lo:.1f}   (x: 0..{ITERS} iterations)")
    out.append("I=INTERACT S=SVR-INTERACT G=GT-DSGD D=D-SGD")
    return "\n".join(out)


def main() -> None:
    from repro.solvers import SolverConfig, expand_grid, make_solver, sweep

    s = make_setup(m=5, n=600)
    configs = expand_grid(SolverConfig(mixing=s.spec, hypergrad=s.hg),
                          algo=ALGORITHMS, seed=SEEDS)
    res = sweep(configs, ITERS, RECORD, problem=s.prob, x0=s.x0, y0=s.y0,
                data=s.data, metric_fn=metric_fn_of(s))
    print(f"{len(configs)} experiments ({len(ALGORITHMS)} algorithms x "
          f"{len(SEEDS)} seeds) in {res.num_dispatches} XLA dispatches, "
          f"{res.seconds:.1f}s batched wall-clock (incl. compile)")

    traces, samples, comms = {}, {}, {}
    for group in res.groups:
        algo = group.config.algo
        mean = res.group_traces(group).mean(axis=0)
        std = res.group_traces(group).std(axis=0)
        traces[algo] = mean.tolist()
        solver = make_solver(SolverConfig(algo=algo))
        samples[algo] = solver.samples_per_step(s.n)
        comms[algo] = solver.communications_per_step
        us = 1e6 * group.seconds / (len(SEEDS) * ITERS)
        print(f"{algo:14s} final M = {mean[-1]:.5f} +- {std[-1]:.5f}   "
              f"({us / 1e3:.1f} ms/iter, {samples[algo]:.0f} IFO "
              "calls/agent/iter)")

    print("\n" + ascii_plot(traces) + "\n")

    print("Table-1 style sample accounting (per agent, to the final M):")
    for algo in ALGORITHMS:
        print(f"  {algo:14s} ~{samples[algo] * ITERS:8.0f} samples, "
              f"{comms[algo] * ITERS} communication rounds")
    print("\nSVR-INTERACT attains INTERACT-level M with "
          f"{samples['svr-interact'] / samples['interact']:.2%} of its "
          "samples per iteration — the sqrt(n) saving of Corollary 4.")


if __name__ == "__main__":
    main()
