"""Paper Section-6 experiment: all four algorithms compared.

Reproduces the Fig. 2 comparison (INTERACT, SVR-INTERACT, GT-DSGD, D-SGD)
on the synthetic meta-learning task and prints an ASCII convergence plot
plus the measured sample counts per agent (Table-1 style).  Every
algorithm is built through the ``repro.solvers`` registry and stepped via
the scan-compiled ``solver.run`` (see benchmarks/common.py).

    PYTHONPATH=src python examples/meta_learning_comparison.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import ALGORITHMS, make_setup, run_algo

ITERS = 40
RECORD = 5


def ascii_plot(traces: dict, width: int = 60, height: int = 14) -> str:
    all_vals = [v for t in traces.values() for v in t]
    lo = np.log10(max(min(all_vals), 1e-8))
    hi = np.log10(max(all_vals))
    rows = [[" "] * width for _ in range(height)]
    markers = {"interact": "I", "svr-interact": "S", "gt-dsgd": "G",
               "d-sgd": "D"}
    for name, trace in traces.items():
        for i, v in enumerate(trace):
            xpos = int(i / max(len(trace) - 1, 1) * (width - 1))
            ynorm = (np.log10(max(v, 1e-8)) - lo) / max(hi - lo, 1e-9)
            ypos = height - 1 - int(ynorm * (height - 1))
            rows[ypos][xpos] = markers[name]
    out = [f"log10(M): {hi:.1f}"]
    out += ["".join(r) for r in rows]
    out.append(f"log10(M): {lo:.1f}   (x: 0..{ITERS} iterations)")
    out.append("I=INTERACT S=SVR-INTERACT G=GT-DSGD D=D-SGD")
    return "\n".join(out)


def main() -> None:
    from repro.solvers import SolverConfig, make_solver

    s = make_setup(m=5, n=600)
    traces, samples, comms = {}, {}, {}
    for algo in ALGORITHMS:
        trace, us, spc = run_algo(s, algo, ITERS, record_every=RECORD)
        traces[algo] = trace
        samples[algo] = spc
        comms[algo] = make_solver(
            SolverConfig(algo=algo)).communications_per_step
        print(f"{algo:14s} final M = {trace[-1]:.5f}   "
              f"({us / 1e3:.1f} ms/iter, {spc:.0f} IFO calls/agent/iter)")

    print("\n" + ascii_plot(traces) + "\n")

    print("Table-1 style sample accounting (per agent, to the final M):")
    for algo in ALGORITHMS:
        print(f"  {algo:14s} ~{samples[algo] * ITERS:8.0f} samples, "
              f"{comms[algo] * ITERS} communication rounds")
    print("\nSVR-INTERACT attains INTERACT-level M with "
          f"{samples['svr-interact'] / samples['interact']:.2%} of its "
          "samples per iteration — the sqrt(n) saving of Corollary 4.")


if __name__ == "__main__":
    main()
