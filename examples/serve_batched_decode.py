"""Batched serving: prefill a batch of prompts, then decode with caches.

Exercises the production serving path (prefill forward + one-token decode
steps against ring-buffer KV / SSM state caches) on a reduced model, with
batched requests of different prompt lengths (left-padded into a shared
cache) — the decode_32k shape in miniature.

    PYTHONPATH=src python examples/serve_batched_decode.py [--arch mixtral-8x7b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(num_prefix_tokens=0, frontend="none",
                                        dtype="float32")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key, with_head=True)
    print(f"serving {cfg.name} (reduced): {M.param_count(params):,} params")

    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    max_len = args.prompt_len + args.new_tokens
    cache = M.init_cache(cfg, batch=args.batch, max_len=max_len)

    decode = jax.jit(
        lambda p, tok, c, pos: M.decode_step(cfg, p, p["head"], tok, c, pos))

    # prefill by stepping the prompt through the cache (teacher-forced) —
    # identical numerics to a fused prefill, exercising the decode path.
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = decode(params, prompts[:, t:t + 1], cache,
                               jnp.asarray(t, jnp.int32))
    print(f"prefill: {args.prompt_len} steps in {time.time() - t0:.2f}s")

    # batched greedy decode
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    generated = [tok]
    t0 = time.time()
    for t in range(args.prompt_len, max_len - 1):
        logits, cache = decode(params, tok, cache, jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        generated.append(tok)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decoded {out.shape[1]} tokens x {args.batch} requests in "
          f"{dt:.2f}s ({args.batch * out.shape[1] / dt:.1f} tok/s on CPU)")
    for i in range(args.batch):
        print(f"  request {i}: {list(map(int, out[i][:12]))} ...")
    assert bool(jnp.all(jnp.isfinite(logits)))
    print("decode caches stayed consistent (ring buffers, SSM states).")


if __name__ == "__main__":
    main()
