"""End-to-end driver: decentralized bilevel LM training with INTERACT.

Trains a ~100M-parameter smollm-family model (reduced depth for CPU; use
--full-width on real hardware) for a few hundred INTERACT steps across 4
agents with heterogeneous token streams — the full production code path:
shard_map consensus (ppermute ring), Neumann hypergradients, per-agent
heads, checkpointing.

    PYTHONPATH=src python examples/decentralized_llm_training.py \
        [--steps 300] [--agents 4]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpoint import save_step
from repro.configs import get_config
from repro.data.synthetic import TokenTaskStream
from repro.launch.train import make_host_mesh
from repro.sharding.compat import set_mesh
from repro.sharding.partition import tree_shardings
from repro.train.bilevel_lm import BilevelHyper
from repro.train.step import (
    InteractConfig, init_train_state, make_train_step, make_eval_step,
    train_state_specs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--per-agent-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full-width", action="store_true",
                    help="use the real 960-wide smollm trunk (slow on CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config("smollm-360m")
    if args.full_width:
        # ~100M params: full width, reduced depth — CPU-tractable yet real.
        cfg = dataclasses.replace(cfg, num_layers=8, vocab_size=49152,
                                  dtype="float32")
    else:
        cfg = cfg.reduced(vocab_size=2048, num_layers=2, d_model=256,
                          d_ff=512, dtype="float32")

    mesh = make_host_mesh(args.agents)
    m = mesh.shape["data"]
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab_size}; mesh={dict(mesh.shape)}; agents={m}")

    icfg = InteractConfig(
        alpha=0.02, beta=0.5,
        hyper=BilevelHyper(mu_g=0.1, neumann_k=3, lipschitz_g=2.0,
                           ce_chunk=min(256, args.seq_len), remat=False))

    state = init_train_state(cfg, jax.random.PRNGKey(0), m)
    specs = train_state_specs(state, mesh)
    state = jax.device_put(state, tree_shardings(mesh, specs))
    stream = TokenTaskStream(vocab_size=cfg.vocab_size, num_agents=m, seed=1)
    step = make_train_step(cfg, mesh, icfg)
    evaluate = make_eval_step(cfg, mesh, icfg)
    tok_shard = NamedSharding(mesh, P("data"))

    with set_mesh(mesh):
        jstep = jax.jit(step, donate_argnums=(0,))
        jeval = jax.jit(evaluate)
        eval_tokens = jax.device_put(
            stream.global_batch(10_000, args.per_agent_batch, args.seq_len),
            tok_shard)
        t0 = time.time()
        for t in range(args.steps):
            tokens = jax.device_put(
                stream.global_batch(t, args.per_agent_batch, args.seq_len),
                tok_shard)
            state, metrics = jstep(state, tokens)
            if (t + 1) % 25 == 0:
                held_out = float(jeval(state, eval_tokens))
                print(f"step {t + 1:4d}  train outer_ce "
                      f"{float(metrics['outer_ce']):.4f}  held-out ce "
                      f"{held_out:.4f}  tracked |u| "
                      f"{float(metrics['grad_norm']):.3e}  "
                      f"({(time.time() - t0) / 25:.2f}s/step)")
                t0 = time.time()
        if args.ckpt_dir:
            save_step(args.ckpt_dir, args.steps, jax.device_get(state))
            print(f"saved final state to {args.ckpt_dir}")

    print("\nEach agent adapted its own head y_i to its token distribution "
          "while the ring consensus kept the backbones synchronized — "
          "decentralized bilevel meta-learning at LM scale.")


if __name__ == "__main__":
    main()
