"""Quickstart: the paper in 60 seconds on CPU.

Runs INTERACT (Algorithm 1) on the Section-6 meta-learning problem with
5 agents over an Erdos-Renyi network through the unified Solver API
(``repro.solvers``), prints the convergence metric
M_t = ||grad l(x_bar)||^2 + consensus error + inner error every few
iterations, and checks the O(1/T) trend.  Stepping goes through the
scan-compiled ``solver.run`` — ten iterations dispatch as one XLA call.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import (
    HypergradConfig, MLPMetaProblem, convergence_metric,
    erdos_renyi_adjacency, init_head, init_mlp_backbone, laplacian_mixing,
    make_synthetic_agents, theorem1_step_sizes,
)
from repro.hypergrad import measure_problem_counts
from repro.solvers import SolverConfig, make_solver


def main() -> None:
    m = 5
    key = jax.random.PRNGKey(0)
    data = make_synthetic_agents(key, num_agents=m, n_per_agent=600,
                                 d_in=16, num_classes=5)
    problem = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(1), d_in=16, hidden=20)
    y0 = init_head(jax.random.PRNGKey(2), hidden=20, num_classes=5)

    adj = erdos_renyi_adjacency(m, p_connect=0.5, seed=3)
    mixing = laplacian_mixing(adj)
    print(f"network: {m} agents, lambda = {mixing.lam:.3f}")

    alpha_max, beta_max = theorem1_step_sizes(
        mu_g=0.5, L_g=4.0, lam=mixing.lam, m=m)
    print(f"Theorem-1 admissible step sizes: alpha<={alpha_max:.2e}, "
          f"beta<={beta_max:.2e} (paper uses 0.5 empirically)")

    # cg-linearized: linearize-once matvecs + early-exit CG — the engine
    # registry's fast path (docs/HYPERGRAD.md); "cg" is the seed oracle.
    hg = HypergradConfig(method="cg", cg_iters=24, backend="cg-linearized")
    cfg = SolverConfig(algo="interact", alpha=0.3, beta=0.3,
                       mixing=mixing, hypergrad=hg)
    solver = make_solver(cfg)
    state = solver.init(None, problem, hg, x0, y0, data)
    counts = measure_problem_counts(problem, hg, x0, y0, data)
    print(f"solver: {cfg.algo}; {solver.samples_per_step(600):.0f} IFO "
          f"calls/agent/iter, {solver.communications_per_step} consensus "
          "rounds/iter")
    print(f"hypergrad backend {hg.resolve_backend()!r}: measured "
          f"{counts.hvp_count} HVPs + {counts.grad_count} grads per call "
          f"(the fixed-budget cg oracle would run {hg.cg_iters + 1})")

    chunk = 10
    for t in range(0, 51, chunk):
        rep = convergence_metric(problem, hg, state.x, state.y,
                                 300, 0.5, data)
        print(f"t={t:3d}  M={float(rep.total):.5f}  "
              f"stationarity={float(rep.stationarity):.5f}  "
              f"consensus={float(rep.consensus_error):.6f}  "
              f"inner={float(rep.inner_error):.5f}  "
              f"outer_loss={float(rep.outer_loss):.4f}")
        if t < 50:
            state = solver.run(state, data, chunk)

    print("\nINTERACT converged; consensus, inner error and stationarity "
          "all driven toward zero simultaneously (eq. 11).")


if __name__ == "__main__":
    main()
